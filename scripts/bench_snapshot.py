"""Machine-readable benchmark snapshot + perf-regression gate.

Runs the FAST benchmark suite (the kernel / quant / per-layer / throughput
/ serving sections of ``benchmarks.run``), parses every emitted CSV row,
and writes a schema-versioned ``BENCH_<UTC-date>.json`` carrying:

* per-section rows (``name -> {us, derived{...}}``) and error status,
* headline numbers (serve tok/s + speedup, per-primitive e2e throughput
  speedups, fused-vs-unfused ratios),
* every ``exact=`` acceptance flag (the bit-exactness contracts),
* a snapshot of the process metrics registry (``repro.obs.metrics``) —
  kernel dispatch counts, tune cache hit/miss/fallback, graph compiles.

The committed ``BENCH_*.json`` files are the repo's bench trajectory: one
snapshot per PR that changes a headline number. Compare two snapshots with

    PYTHONPATH=src python scripts/bench_snapshot.py --compare latest

which re-runs the suite and exits non-zero on any regression:

* **hard failures** (always): a lost ``exact=1`` flag, a section or row
  that disappeared (coverage), or — unless ``--latency-warn-only`` — a
  latency/throughput regression beyond ``--threshold`` percent.
* **warnings** (exit 0): latency/throughput drifts under
  ``--latency-warn-only``, the right mode for interpret-mode CI runners
  whose absolute timings are noisy; exactness/coverage still hard-fail.

``--trace out.json`` additionally enables ``repro.obs`` tracing for the
run and exports the Chrome trace (CI uploads it as a workflow artifact).
"""
from __future__ import annotations

import argparse
import contextlib
import datetime
import glob
import io
import json
import os
import sys
import traceback
from typing import Dict, List, Optional, Tuple

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, ROOT)            # the benchmarks/ namespace package

SCHEMA_VERSION = 1

# The sections whose headline numbers the snapshot records, in run order.
SECTIONS = ("kernels", "quant", "layers", "throughput", "serving")

# derived keys where bigger is better; everything else numeric (and the us
# column) is treated as lower-better latency when compared
HIGHER_BETTER = ("tok_s", "images_per_s", "loop_images_per_s", "speedup",
                 "continuous_over_static", "reuse_gain", "concurrent_ratio",
                 "ttft_speedup", "hit_rate", "paged_prefix_toks",
                 "serve_degraded_ratio", "degraded_ratio")


# --------------------------------------------------------------------------
# Run + parse
# --------------------------------------------------------------------------

def _coerce(v: str):
    """CSV derived values -> float where possible ('2.31x' included)."""
    for s in (v, v[:-1] if v.endswith("x") else v):
        try:
            return float(s)
        except ValueError:
            continue
    return v


def parse_rows(text: str) -> Dict[str, dict]:
    rows: Dict[str, dict] = {}
    for line in text.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) < 2 or parts[0] in ("name", "done"):
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        derived = {}
        if len(parts) == 3 and parts[2]:
            for kv in parts[2].split(";"):
                if "=" in kv:
                    k, v = kv.split("=", 1)
                    derived[k] = _coerce(v)
        rows[parts[0]] = {"us": us, "derived": derived}
    return rows


def _section_mains():
    from benchmarks import (kernels_bench, layer_bench, quant_bench,
                            serve_bench, throughput_bench)
    return {"kernels": kernels_bench.main, "quant": quant_bench.main,
            "layers": layer_bench.main, "throughput": throughput_bench.main,
            "serving": serve_bench.main}


def run_sections(names) -> Dict[str, dict]:
    mains = _section_mains()
    out: Dict[str, dict] = {}
    for name in names:
        buf = io.StringIO()
        err: Optional[str] = None
        try:
            with contextlib.redirect_stdout(buf):
                mains[name]()
        except Exception as e:      # noqa: BLE001 — record, keep snapshotting
            err = f"{type(e).__name__}: {e}"
            traceback.print_exc(file=sys.stderr)
        out[name] = {"ok": err is None, "error": err,
                     "rows": parse_rows(buf.getvalue())}
        status = "ok" if err is None else f"ERROR ({err})"
        print(f"bench_snapshot: section {name}: "
              f"{len(out[name]['rows'])} rows, {status}")
    return out


# --------------------------------------------------------------------------
# Snapshot assembly
# --------------------------------------------------------------------------

def collect_exact(sections: Dict[str, dict]) -> Dict[str, float]:
    """Every row-level ``exact=`` acceptance flag, keyed by row name."""
    return {rname: row["derived"]["exact"]
            for sec in sections.values()
            for rname, row in sec["rows"].items()
            if "exact" in row["derived"]}


def collect_headline(sections: Dict[str, dict]) -> Dict[str, float]:
    h: Dict[str, float] = {}
    srows = sections.get("serving", {}).get("rows", {})
    for sched in ("static", "continuous"):
        row = srows.get(f"serve/{sched}")
        if row and "tok_s" in row["derived"]:
            h[f"serve_{sched}_tok_s"] = row["derived"]["tok_s"]
    sp = srows.get("serve/speedup")
    if sp and "continuous_over_static" in sp["derived"]:
        h["serve_speedup"] = sp["derived"]["continuous_over_static"]
    # §Paged-KV: paged tok/s on the shared-prefix workload plus the two
    # budget-matched ratio claims (exact flag rides in via collect_exact)
    gain = srows.get("serve/prefix/gain")
    if gain:
        for key in ("paged_prefix_toks", "concurrent_ratio", "ttft_speedup"):
            if key in gain["derived"]:
                h[key] = gain["derived"][key]
    # §Resilience: throughput retained under the injected-fault drain and
    # the deterministic shed fraction (its exact flag rides in via
    # collect_exact and is mandatory — see check below)
    res = srows.get("serve/resilience")
    if res:
        if "degraded_ratio" in res["derived"]:
            h["serve_degraded_ratio"] = res["derived"]["degraded_ratio"]
        if "shed_rate" in res["derived"]:
            h["serve_shed_rate"] = res["derived"]["shed_rate"]
    for rname, row in sections.get("throughput", {}).get("rows", {}).items():
        if rname.endswith("/e2e") and "speedup" in row["derived"]:
            prim = rname.split("/")[1]
            h[f"throughput_{prim}_speedup"] = row["derived"]["speedup"]
    eng = sections.get("throughput", {}).get("rows", {}).get(
        "throughput/serve/engine")
    if eng and "images_per_s" in eng["derived"]:
        h["cnn_engine_images_per_s"] = eng["derived"]["images_per_s"]
    for rname, row in sections.get("layers", {}).get("rows", {}).items():
        if rname.endswith("/e2e") and "fused_over_unfused" in row["derived"]:
            prim = rname.split("/")[1]
            h[f"layers_{prim}_fused_over_unfused"] = \
                row["derived"]["fused_over_unfused"]
    # W4A8 (§Sub-byte): weight-bytes-moved ratio per primitive — the
    # headline sub-byte claim (≈0.5 + group-shift sideband). The per-row
    # exact flags ride in via collect_exact like every other section.
    for rname, row in sections.get("quant", {}).get("rows", {}).items():
        if rname.startswith("quant_w4/") and "wbytes_ratio" in row["derived"]:
            prim = rname.split("/")[1]
            h[f"w4_{prim}_wbytes_ratio"] = row["derived"]["wbytes_ratio"]
    return h


def build_snapshot(section_names) -> dict:
    from benchmarks.common import FAST
    from repro.obs import metrics as obs_metrics
    from repro.tune.runner import backend_tag
    sections = run_sections(section_names)
    return {
        "schema_version": SCHEMA_VERSION,
        "created_utc": datetime.datetime.now(datetime.timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
        "fast": FAST,
        "backend": backend_tag(),
        "sections": sections,
        "headline": collect_headline(sections),
        "exact": collect_exact(sections),
        "metrics": obs_metrics.snapshot(),
    }


# --------------------------------------------------------------------------
# Compare (the perf gate)
# --------------------------------------------------------------------------

def _pct_worse(cur: float, prev: float, higher_better: bool) -> float:
    """Regression percentage (positive = worse), 0 when prev is ~0."""
    if prev <= 0:
        return 0.0
    return ((prev - cur) / prev if higher_better
            else (cur - prev) / prev) * 100.0


def compare(cur: dict, prev: dict, *, threshold: float,
            latency_hard: bool) -> Tuple[List[str], List[str]]:
    """Returns (failures, warnings). Exactness and coverage regressions are
    always failures; latency/throughput drifts beyond ``threshold`` percent
    are failures when ``latency_hard`` else warnings."""
    failures: List[str] = []
    warnings: List[str] = []

    if cur.get("fast") != prev.get("fast"):
        warnings.append(
            f"mode mismatch: cur fast={cur.get('fast')} vs "
            f"prev fast={prev.get('fast')} — timings are not comparable")
    if cur.get("backend") != prev.get("backend"):
        warnings.append(
            f"backend mismatch: {cur.get('backend')} vs {prev.get('backend')}"
            " — timings are not comparable")

    # coverage: sections and rows present before must still be present + ok
    for sec, pdata in prev.get("sections", {}).items():
        cdata = cur.get("sections", {}).get(sec)
        if not pdata.get("ok"):
            continue
        if cdata is None or not cdata.get("ok"):
            failures.append(
                f"coverage: section {sec!r} was ok in the baseline but is "
                f"{'missing' if cdata is None else 'failing'} now"
                + (f" ({cdata['error']})" if cdata and cdata.get("error")
                   else ""))
            continue
        for rname in pdata.get("rows", {}):
            if rname not in cdata.get("rows", {}):
                failures.append(
                    f"coverage: row {rname!r} disappeared from {sec!r}")

    # exactness: a 1 -> not-1 transition is a broken bit-exactness contract
    for key, pv in prev.get("exact", {}).items():
        cv = cur.get("exact", {}).get(key)
        if pv == 1 and cv != 1:
            failures.append(
                f"exactness: {key} was exact=1 in the baseline, now "
                f"exact={cv!r}")

    # latency/throughput: us column (lower-better) + known derived keys
    lat_sink = failures if latency_hard else warnings
    for sec, pdata in prev.get("sections", {}).items():
        cdata = cur.get("sections", {}).get(sec)
        if cdata is None:
            continue
        for rname, prow in pdata.get("rows", {}).items():
            crow = cdata.get("rows", {}).get(rname)
            if crow is None:
                continue
            worse = _pct_worse(crow["us"], prow["us"], higher_better=False)
            if prow["us"] > 0 and worse > threshold:
                lat_sink.append(
                    f"latency: {rname} us {prow['us']:.1f} -> "
                    f"{crow['us']:.1f} (+{worse:.0f}% > {threshold:.0f}%)")
            for k in HIGHER_BETTER:
                pv, cv = prow["derived"].get(k), crow["derived"].get(k)
                if (isinstance(pv, float) and isinstance(cv, float)
                        and pv > 0):
                    worse = _pct_worse(cv, pv, higher_better=True)
                    if worse > threshold:
                        lat_sink.append(
                            f"throughput: {rname} {k} {pv:.2f} -> {cv:.2f} "
                            f"(-{worse:.0f}% > {threshold:.0f}%)")
    return failures, warnings


def resolve_baseline(arg: str, out_path: str) -> str:
    """--compare PATH, or --compare latest -> newest committed BENCH_*.json
    at the repo root (excluding the file this run is about to write)."""
    if arg != "latest":
        return arg
    cands = sorted(p for p in glob.glob(os.path.join(ROOT, "BENCH_*.json"))
                   if os.path.abspath(p) != os.path.abspath(out_path))
    if not cands:
        raise SystemExit("bench_snapshot: --compare latest found no "
                         "committed BENCH_*.json baseline")
    return cands[-1]


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None,
                    help="snapshot path (default: <repo>/BENCH_<UTC-date>"
                         ".json)")
    ap.add_argument("--sections", default=",".join(SECTIONS),
                    help=f"comma list from {SECTIONS}")
    ap.add_argument("--compare", default=None, metavar="PREV",
                    help="baseline BENCH_*.json (or 'latest'); exit non-zero "
                         "on regression")
    ap.add_argument("--threshold", type=float, default=10.0,
                    help="regression threshold in percent (default 10)")
    ap.add_argument("--latency-warn-only", action="store_true",
                    help="latency/throughput drifts warn instead of failing "
                         "(exactness/coverage still hard-fail) — for "
                         "interpret-mode CI runners")
    ap.add_argument("--trace", default=None, metavar="TRACE_JSON",
                    help="enable repro.obs tracing and export the Chrome "
                         "trace here")
    args = ap.parse_args(argv)

    # FAST by default: the snapshot is the CI-sized suite unless the caller
    # explicitly opts out with REPRO_BENCH_FAST=0 in the environment
    os.environ.setdefault("REPRO_BENCH_FAST", "1")

    names = [s.strip() for s in args.sections.split(",") if s.strip()]
    unknown = [s for s in names if s not in SECTIONS]
    if unknown:
        ap.error(f"unknown sections {unknown}; choose from {SECTIONS}")

    date = datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%d")
    out_path = args.out or os.path.join(ROOT, f"BENCH_{date}.json")

    if args.trace:
        os.environ[
            "REPRO_TRACE"] = "1"     # before any repro import reads it
    from repro.obs import trace as obs_trace
    if args.trace:
        obs_trace.enable()

    snap = build_snapshot(names)

    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True)
    print(f"bench_snapshot: wrote {out_path} "
          f"({len(snap['headline'])} headline numbers, "
          f"{len(snap['exact'])} exact flags)")

    if args.trace:
        obs_trace.export(args.trace)
        print(f"bench_snapshot: wrote trace {args.trace} "
              f"({len(obs_trace.TRACER.events())} events)")

    rc = 0
    if args.compare:
        base_path = resolve_baseline(args.compare, out_path)
        with open(base_path) as f:
            prev = json.load(f)
        if prev.get("schema_version") != SCHEMA_VERSION:
            print(f"bench_snapshot: baseline {base_path} has schema "
                  f"{prev.get('schema_version')} != {SCHEMA_VERSION}; "
                  "skipping compare")
            return 0
        failures, warnings = compare(
            snap, prev, threshold=args.threshold,
            latency_hard=not args.latency_warn_only)
        for w in warnings:
            print(f"WARN: {w}")
        for e in failures:
            print(f"REGRESSION: {e}")
        print(f"bench_snapshot: compared against {base_path}: "
              f"{len(failures)} regression(s), {len(warnings)} warning(s)")
        rc = 1 if failures else 0
    return rc


if __name__ == "__main__":
    sys.exit(main())
