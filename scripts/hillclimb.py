"""§Perf hillclimb driver: run named variants of the three chosen cells,
tag the artifacts, and print before/after roofline terms.

Variants are (hypothesis -> change) pairs from EXPERIMENTS.md §Perf;
each lowers + compiles the cell with a modified TrainConfig / ShardingRules
and records a tagged artifact next to the baseline.
"""
import argparse
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(ROOT, "artifacts", "dryrun")

# (cell, variant_tag, env knobs consumed by dryrun via REPRO_* )
RUNS = [
    # paper-representative: falcon-mamba (conv1d primitive in the LM stack)
    ("falcon-mamba-7b", "train_4k", "single", "hc_tri",
     {"REPRO_ATTN_IMPL": "flash_tri"}),
    ("falcon-mamba-7b", "train_4k", "single", "hc_dots",
     {"REPRO_REMAT": "dots"}),
    ("falcon-mamba-7b", "train_4k", "single", "hc_tri_dots",
     {"REPRO_ATTN_IMPL": "flash_tri", "REPRO_REMAT": "dots"}),
    # worst flops-ratio: qwen2-0.5b train (replicated attention over model)
    ("qwen2-0.5b", "train_4k", "single", "hc_tri",
     {"REPRO_ATTN_IMPL": "flash_tri"}),
    ("qwen2-0.5b", "train_4k", "single", "hc_seqshard",
     {"REPRO_SEQ_SHARD": "1"}),
    ("qwen2-0.5b", "train_4k", "single", "hc_seq_tri",
     {"REPRO_SEQ_SHARD": "1", "REPRO_ATTN_IMPL": "flash_tri"}),
    ("qwen2-0.5b", "train_4k", "single", "hc_seq_tri_dots",
     {"REPRO_SEQ_SHARD": "1", "REPRO_ATTN_IMPL": "flash_tri",
      "REPRO_REMAT": "dots"}),
    # most collective-bound: arctic train multi-pod (EP a2a + ZeRO gathers)
    ("arctic-480b", "train_4k", "multi", "hc_podlocal",
     {"REPRO_POD_LOCAL_FSDP": "1"}),
    ("arctic-480b", "train_4k", "multi", "hc_tri",
     {"REPRO_ATTN_IMPL": "flash_tri"}),
    ("arctic-480b", "train_4k", "multi", "hc_tri_podlocal",
     {"REPRO_ATTN_IMPL": "flash_tri", "REPRO_POD_LOCAL_FSDP": "1"}),
    # hypothesis: ZeRO weight gathers repeat per microbatch; with sharded
    # residuals mb=1 fits memory and divides gather traffic by 8
    ("arctic-480b", "train_4k", "multi", "hc_mb1_tri",
     {"REPRO_MICROBATCHES": "1", "REPRO_SHARD_RESIDUALS": "1",
      "REPRO_ATTN_IMPL": "flash_tri"}),
    ("falcon-mamba-7b", "train_4k", "single", "hc_mb1_tri",
     {"REPRO_MICROBATCHES": "1", "REPRO_SHARD_RESIDUALS": "1",
      "REPRO_ATTN_IMPL": "flash_tri"}),
    ("arctic-480b", "train_4k", "multi", "hc_mb2_tri",
     {"REPRO_MICROBATCHES": "2", "REPRO_SHARD_RESIDUALS": "1",
      "REPRO_ATTN_IMPL": "flash_tri"}),
]


def term_str(rec):
    h = rec["hlo"]
    comp = h["dot_flops"] / 197e12
    coll = h["coll_bytes_ici"] / (4 * 50e9) + h["coll_bytes_dcn"] / 25e9
    ratio = rec["model_flops"] / max(rec["n_chips"] * h["dot_flops"], 1)
    return (f"compute={comp*1e3:8.1f}ms coll={coll*1e3:8.1f}ms "
            f"ratio={ratio:.3f} "
            f"peak_tpu={rec['memory'].get('peak_bytes_tpu', 0)/2**30:6.2f}GiB")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    env0 = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    for arch, shape, mp, tag, knobs in RUNS:
        if args.only and args.only not in (arch, tag):
            continue
        meshname = "2x16x16" if mp == "multi" else "16x16"
        cell = f"{arch}__{shape}__{meshname}__{tag}"
        path = os.path.join(ART, cell + ".json")
        if not os.path.exists(path):
            env = dict(env0, **knobs)
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                 "--shape", shape, "--multi-pod", mp, "--tag", tag],
                env=env, cwd=ROOT, capture_output=True, text=True)
            print((r.stdout or "").strip().splitlines()[-1:] or
                  [f"rc={r.returncode} {(r.stderr or '')[-200:]}"])
        base_path = os.path.join(ART, f"{arch}__{shape}__{meshname}__baseline.json")
        if os.path.exists(path) and os.path.exists(base_path):
            rec = json.load(open(path))
            base = json.load(open(base_path))
            if rec.get("status") == "ok" and base.get("status") == "ok":
                print(f"{arch}/{shape}/{meshname}")
                print(f"  baseline   {term_str(base)}")
                print(f"  {tag:10s} {term_str(rec)}")
            else:
                print(f"{cell}: {rec.get('status')} {rec.get('error','')[:120]}")


if __name__ == "__main__":
    main()
