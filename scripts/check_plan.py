"""Static-verification CLI: every ``repro.check`` pass over the committed
artifacts, exercised plans, and the source tree — no kernel executes.

Sections, in run order:

1. **cache** — re-verify every entry of the committed tune cache
   (``artifacts/tune_cache.json``) against the authoritative VMEM
   footprint model (``repro.check.footprint.audit_cache``). An entry that
   no longer fits the budget is an error (re-tune or drop it);
   ``--write-audit`` persists the row-level result to
   ``artifacts/tune_cache_audit.json``.
2. **plans** — lower one small CNN plan per primitive x weight width
   (int8 and W4A8) and run the dataflow abstract interpreter plus the
   int32-accumulator / requant-shift range analysis over each. These are
   the same passes ``CompiledPlan`` runs at build time; here they gate CI.
3. **serve** — ``check_serve_config`` over the default LM ServeConfig
   against a small ModelConfig, and ``check_cnn_serve_config`` over the
   default CNN config.
4. **lint** — the AST lint (``repro.check.astlint``) over ``src/`` and
   ``scripts/``: Pallas index-map default-arg captures, ``time.time()``
   elapsed timing, timers stopped before ``block_until_ready``.

Exit status: non-zero on any error; ``--strict`` also promotes warnings
(schedule degradation notes, submit-time serve-config hazards) to
failures. Lowering needs a few seconds of CPU tracing — run with
``JAX_PLATFORMS=cpu REPRO_PALLAS_INTERPRET=1`` on CI runners.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

AUDIT_PATH = os.path.join(ROOT, "artifacts", "tune_cache_audit.json")

# one plan per primitive per weight width; small shapes keep lowering
# cheap while still crossing every scale-chain / fusion rule
PLAN_WIDTHS = (8, 12)
PLAN_IMAGE = 16
WEIGHT_BITS = (8, 4)


def section(title: str):
    print(f"\n== {title} " + "=" * max(0, 66 - len(title)))


def run_cache(args, errors: List[str], warnings: List[str]) -> None:
    from repro.check import audit_cache
    from repro.check.footprint import summarize_audit
    from repro.tune import cache as tune_cache

    section("tune cache audit")
    path = args.cache or tune_cache.default_cache_path()
    if path is None or not os.path.exists(path):
        print("no persistent tune cache found — nothing to audit")
        return
    rows = audit_cache(path)
    summ = summarize_audit(rows)
    print(f"cache: {path}")
    print(f"entries={summ['entries']} feasible={summ['feasible']} "
          f"warnings={summ['warnings']} notes={summ['notes']}")
    for r in rows:
        if not r["ok"]:
            for e in r["errors"]:
                errors.append(f"cache[{r['key']}]: {e}")
        for w in r["warnings"]:
            warnings.append(f"cache[{r['key']}]: {w}")
        for n in r["notes"]:
            print(f"note: {r['key']}: {n}")
    if args.write_audit:
        blob = {"cache": os.path.relpath(path, ROOT),
                "summary": summ, "rows": rows}
        with open(AUDIT_PATH, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {os.path.relpath(AUDIT_PATH, ROOT)}")


def run_plans(errors: List[str], warnings: List[str]) -> None:
    import jax

    from repro.check import overflow_errors
    from repro.check.dataflow import check_plan
    from repro.check.overflow import check_plan_overflow
    from repro.core import Primitives
    from repro.graph import build_cnn_graph, lower
    from repro.models.convnet import CNNConfig, init_cnn

    section("plan dataflow + overflow")
    for prim in Primitives:
        cfg = CNNConfig(primitive=prim, widths=PLAN_WIDTHS,
                        image_size=PLAN_IMAGE)
        params = init_cnn(cfg, jax.random.PRNGKey(1))
        calib = jax.random.normal(jax.random.PRNGKey(2),
                                  (4, PLAN_IMAGE, PLAN_IMAGE, 3)) * 0.5
        graph = build_cnn_graph(cfg)
        for bits in WEIGHT_BITS:
            plan = lower(graph, params, calib, weight_bits=bits)
            diags = check_plan(plan)
            for d in diags:
                line = f"plan[{prim}/w{bits}] {d.node}: {d.message}"
                (errors if d.level == "error" else warnings).append(line)
            bounds = check_plan_overflow(plan)
            for e in overflow_errors(bounds):
                errors.append(f"plan[{prim}/w{bits}] {e}")
            worst = min(b.headroom_bits for b in bounds)
            flags = sum(1 for d in diags if d.level == "error") \
                + len(overflow_errors(bounds))
            print(f"{prim:>8s}/w{bits}: nodes={len(plan.nodes)} "
                  f"bounds={len(bounds)} min_headroom={worst:.1f}b "
                  f"{'FAIL' if flags else 'ok'}")


def run_serve(args, errors: List[str], warnings: List[str]) -> None:
    from repro.check import check_cnn_serve_config, check_serve_config
    from repro.configs.base import ModelConfig
    from repro.serve.cnn import CNNServeConfig
    from repro.serve.engine import ServeConfig

    section("serve configs")
    cfg = ModelConfig(name="check-tiny", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab=256)
    checks = [
        ("lm/default", check_serve_config(ServeConfig(), cfg,
                                          strict=args.strict)),
        ("lm/int8-kv", check_serve_config(
            ServeConfig(precision="int8", kv_cache="int8"), cfg,
            strict=args.strict)),
        ("lm/paged", check_serve_config(
            ServeConfig(kv_layout="paged"), cfg, strict=args.strict)),
        ("cnn/default", check_cnn_serve_config(CNNServeConfig())),
        # §Resilience knobs: a fault-hardened config (deadline + capped
        # queue + drop shedding) must validate clean on both engines
        ("lm/faulted", check_serve_config(
            ServeConfig(deadline_s=5.0, max_queue=16, shed_policy="drop",
                        max_retries=3, retry_backoff_s=0.01), cfg,
            strict=args.strict)),
        ("cnn/faulted", check_cnn_serve_config(
            CNNServeConfig(deadline_s=5.0, max_queue=16,
                           shed_policy="drop", max_retries=3,
                           retry_backoff_s=0.01))),
    ]
    for name, errs in checks:
        print(f"{name}: {'FAIL' if errs else 'ok'}")
        errors.extend(f"serve[{name}]: {e}" for e in errs)


def run_lint(errors: List[str]) -> None:
    from repro.check.astlint import lint_paths

    section("ast lint")
    findings = lint_paths([os.path.join(ROOT, "src"),
                           os.path.join(ROOT, "scripts")])
    print(f"findings={len(findings)}")
    for f in findings:
        errors.append(f"lint: {f.path}:{f.line}: [{f.rule}] {f.message}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--strict", action="store_true",
                    help="promote warnings to failures and enable "
                         "submit-time serve-config checks")
    ap.add_argument("--cache", default=None,
                    help="tune cache path (default: committed cache)")
    ap.add_argument("--write-audit", action="store_true",
                    help=f"write row-level cache audit to "
                         f"{os.path.relpath(AUDIT_PATH, ROOT)}")
    ap.add_argument("--skip-plans", action="store_true",
                    help="skip plan lowering (fast artifact-only mode)")
    args = ap.parse_args(argv)

    errors: List[str] = []
    warnings: List[str] = []
    run_cache(args, errors, warnings)
    if not args.skip_plans:
        run_plans(errors, warnings)
    run_serve(args, errors, warnings)
    run_lint(errors)

    section("summary")
    for w in warnings:
        print(f"warning: {w}")
    for e in errors:
        print(f"error: {e}")
    fail = bool(errors) or (args.strict and bool(warnings))
    print(f"{len(errors)} error(s), {len(warnings)} warning(s)"
          + (" [strict]" if args.strict else ""))
    return 1 if fail else 0


if __name__ == "__main__":
    sys.exit(main())
