"""Pre-tune the Pallas kernels over the paper's Table-2 sweep shapes and
commit a persistent config cache.

    PYTHONPATH=src python scripts/tune.py --shapes table2 --out tuned.json

The committed artifacts/tune_cache.json (schema v2 — the batched/tiled
block_n/block_h/block_w spaces) is regenerated with:

    PYTHONPATH=src python scripts/tune.py --shapes table2 \
        --cnn standard,dws,shift,add --cnn-batch 8 \
        --out artifacts/tune_cache.json

The resulting JSON can be installed for the dispatch layer either by saving
it to artifacts/tune_cache.json (the default lookup location) or by
pointing REPRO_TUNE_CACHE at it. Without any cache, kernels run on the
analytic-fallback schedule — this script is an optimization, never a
requirement.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax
import jax.numpy as jnp

from repro import tune

KEY = jax.random.PRNGKey(0)


def _f32(shape):
    return jax.random.normal(KEY, shape, jnp.float32)


def _i8(shape):
    return jax.random.randint(KEY, shape, -100, 100, jnp.int32).astype(jnp.int8)


# int8 jobs time the kernels' fused requantized epilogue (Algorithm 1): a
# representative per-layer shift, held fixed across candidate schedules.
_REQUANT = 7


def _qkw(dtype, **extra):
    kw = dict(extra)
    if dtype == "int8":
        kw["requant_shift"] = _REQUANT
    return kw or None


def _conv2d(n, h, w, ci, co, k, g=1, dtype="float32"):
    mk = _i8 if dtype == "int8" else _f32
    return ("conv2d", tune.sig_conv2d(n, h, w, ci, co, k, g),
            (mk((n, h, w, ci)), mk((k, k, ci // g, co))), dtype,
            _qkw(dtype, groups=g))


def _depthwise(n, h, w, c, k, dtype="float32"):
    mk = _i8 if dtype == "int8" else _f32
    return ("depthwise2d", tune.sig_depthwise2d(n, h, w, c, k),
            (mk((n, h, w, c)), mk((k, k, c))), dtype, _qkw(dtype))


def _shift(n, h, w, c, co, dtype="float32"):
    mk = _i8 if dtype == "int8" else _f32
    shifts = jnp.array([[(i % 3) - 1, ((i // 3) % 3) - 1] for i in range(c)],
                       jnp.int32)
    return ("shift_conv2d", tune.sig_shift_conv2d(n, h, w, c, co),
            (mk((n, h, w, c)), shifts, mk((c, co))), dtype, _qkw(dtype))


def _add(n, h, w, ci, co, k, dtype="float32"):
    mk = _i8 if dtype == "int8" else _f32
    return ("add_conv2d", tune.sig_add_conv2d(n, h, w, ci, co, k),
            (mk((n, h, w, ci)), mk((k, k, ci, co))), dtype, _qkw(dtype))


def _pool(n, h, w, c, window, stride, dtype="int8"):
    mk = _i8 if dtype == "int8" else _f32
    return ("maxpool2d", tune.sig_maxpool2d(n, h, w, c, window, stride),
            (mk((n, h, w, c)),), dtype, dict(window=window, stride=stride))


def _c1d(b, l, d, k):
    return ("causal_conv1d", tune.sig_causal_conv1d(b, l, d, k),
            (_f32((b, l, d)), _f32((k, d))), "float32")


def _matmul(m, k, n, dtype="float32"):
    mk = _i8 if dtype == "int8" else _f32
    return ("matmul", tune.sig_matmul(m, k, n), (mk((m, k)), mk((k, n))), dtype,
            _qkw(dtype))


def shapes_table2():
    """The paper's Table-2 sweep plan, one tuning job per (primitive, axis
    extreme): groups / kernel size / width / cin / cout, plus the LM-side
    shapes (matmul_q8, Mamba causal conv1d) the kernels also serve."""
    return [
        # exp1 groups sweep @ w=10, ci=128, co=64, k=3
        _conv2d(1, 10, 10, 128, 64, 3, 1),
        _conv2d(1, 10, 10, 128, 64, 3, 4),
        # exp2 kernel-size sweep @ w=32, ci=co=16
        _conv2d(1, 32, 32, 16, 16, 3),
        _conv2d(1, 32, 32, 16, 16, 7),
        # exp3/4/5 width / cin / cout extremes
        _conv2d(1, 8, 8, 16, 16, 3),
        _conv2d(1, 32, 32, 32, 32, 3),
        # non-standard primitives at the sweep's center point
        _depthwise(1, 32, 32, 64, 3),
        _shift(1, 32, 32, 64, 64),
        _add(1, 10, 10, 16, 16, 3),
        # integer-only (Algorithm 1) variants: the qconv_apply(method="pallas")
        # path looks these up per (kernel, shape, int8) — same shapes as the
        # float jobs so pallas-int8 vs float compares tuned-vs-tuned
        _conv2d(1, 10, 10, 128, 64, 3, 1, dtype="int8"),
        _conv2d(1, 10, 10, 128, 64, 3, 4, dtype="int8"),
        _conv2d(1, 32, 32, 16, 16, 3, dtype="int8"),
        _depthwise(1, 32, 32, 64, 3, dtype="int8"),
        _shift(1, 32, 32, 64, 64, dtype="int8"),
        _add(1, 10, 10, 16, 16, 3, dtype="int8"),
        # batched serving shapes: the block_n/block_h/block_w halves of the
        # tiled-grid spaces are live here (at n=1 they dedupe away)
        _conv2d(8, 32, 32, 16, 16, 3, dtype="int8"),
        _depthwise(8, 32, 32, 64, 3, dtype="int8"),
        _shift(8, 32, 32, 64, 64, dtype="int8"),
        _add(8, 10, 10, 16, 16, 3, dtype="int8"),
        _pool(8, 32, 32, 64, 2, 2),
        # LM-side kernels
        _c1d(2, 512, 256, 4),
        _matmul(256, 512, 256),
        _matmul(512, 512, 512),
        _matmul(256, 256, 256, dtype="int8"),
        _matmul(512, 512, 512, dtype="int8"),
    ]


def shapes_smoke():
    """Tiny job list for CI / fast sanity runs."""
    return [
        _conv2d(1, 8, 8, 8, 16, 3),
        _conv2d(1, 8, 8, 8, 16, 3, dtype="int8"),
        _depthwise(1, 8, 8, 16, 3),
        _add(1, 6, 6, 4, 8, 3),
        _matmul(64, 64, 64),
        _matmul(64, 64, 64, dtype="int8"),
    ]


SHAPE_SETS = {"table2": shapes_table2, "smoke": shapes_smoke}


def cnn_plan_jobs(primitives: str, *, widths=(16, 32, 64), image_size=32,
                  batch=1):
    """Whole-plan pre-tuning through repro.graph: lower one CNN per
    requested primitive and emit every kernel invocation of its plan as a
    tuning job (``tune.plan_jobs``), so a deployed CompiledPlan finds every
    node's schedule in the cache."""
    from repro.graph import build_cnn_graph, lower
    from repro.models.convnet import CNNConfig, init_cnn

    jobs = []
    for i, prim in enumerate(primitives.split(",")):
        cfg = CNNConfig(primitive=prim.strip(), widths=tuple(widths),
                        image_size=image_size)
        params = init_cnn(cfg, jax.random.PRNGKey(i))
        calib = jax.random.normal(jax.random.PRNGKey(100 + i),
                                  (4, image_size, image_size,
                                   cfg.in_channels)) * 0.5
        plan = lower(build_cnn_graph(cfg), params, calib)
        jobs.extend(tune.plan_jobs(plan, batch=batch))
    return jobs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shapes", choices=sorted(SHAPE_SETS), default="table2")
    ap.add_argument("--cnn", default="",
                    help="comma-separated CNN primitives: pre-tune each "
                         "model's WHOLE lowered plan (repro.graph) in one "
                         "call, e.g. --cnn standard,dws,shift")
    ap.add_argument("--cnn-batch", type=int, default=1,
                    help="batch size the --cnn plans are tuned at (cache "
                         "keys include the batch dim — tune at the batch "
                         "you deploy)")
    ap.add_argument("--out", default="tuned.json")
    ap.add_argument("--kernels", default="",
                    help="comma-separated kernel filter (default: all)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--max-candidates", type=int, default=None)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    jobs = SHAPE_SETS[args.shapes]()
    if args.cnn:
        jobs += cnn_plan_jobs(args.cnn, batch=args.cnn_batch)
    if args.kernels:
        keep = set(args.kernels.split(","))
        jobs = [j for j in jobs if j[0] in keep]

    cache = tune.TuneCache(None)
    backend = tune.backend_tag()
    print(f"# tuning {len(jobs)} (kernel, shape) jobs on backend={backend}")
    wins = 0
    for job in jobs:
        kernel, sig, arrays, dtype = job[:4]
        kwargs = job[4] if len(job) > 4 else None
        best, best_us = tune.autotune_into(
            cache, kernel, sig, arrays, dtype, kwargs=kwargs, reps=args.reps,
            warmup=args.warmup, max_candidates=args.max_candidates,
            verbose=args.verbose)
        entry = cache.get(tune.cache_key(kernel, sig.key(), dtype, backend))
        d_us = entry.get("default_us")
        sp = (d_us / best_us) if (d_us and best_us) else float("nan")
        tag = "TUNED-WIN" if d_us and best_us < d_us else "default-best"
        wins += tag == "TUNED-WIN"
        print(f"{kernel}/{sig.key()}/{dtype}: best={best} {best_us:.1f}us "
              f"default={d_us and round(d_us, 1)}us speedup={sp:.2f}x [{tag}]")

    cache.save(args.out)
    print(f"# wrote {len(cache)} entries -> {args.out} "
          f"({wins}/{len(jobs)} shapes improved over the default schedule)")
    print(f"# install: cp {args.out} artifacts/tune_cache.json  "
          f"(or REPRO_TUNE_CACHE={args.out})")


if __name__ == "__main__":
    main()
