"""Dry-run sweep orchestrator: one subprocess per cell (fresh memory, rlimit
inside, per-cell timeout) so a pathological cell is recorded as an error
instead of killing the sweep. Resumable via --skip-done semantics."""
import argparse
import json
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(ROOT, "artifacts", "dryrun")

ARCHS = ["internvl2-1b", "arctic-480b", "granite-moe-1b-a400m", "granite-34b",
         "qwen1.5-32b", "granite-3-2b", "qwen2-0.5b", "seamless-m4t-large-v2",
         "jamba-v0.1-52b", "falcon-mamba-7b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def done(cell):
    p = os.path.join(ART, cell + ".json")
    if not os.path.exists(p):
        return False
    try:
        return json.load(open(p)).get("status") in ("ok", "skipped")
    except Exception:
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--timeout", type=int, default=1500)
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--meshes", default="both")
    ap.add_argument("--retry-errors", action="store_true")
    args = ap.parse_args()
    meshes = {"both": [("single", "16x16"), ("multi", "2x16x16")],
              "single": [("single", "16x16")],
              "multi": [("multi", "2x16x16")]}[args.meshes]
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    for arch in ARCHS:
        for shape in SHAPES:
            for mp, meshname in meshes:
                cell = f"{arch}__{shape}__{meshname}__{args.tag}"
                if done(cell):
                    print(f"[have] {cell}", flush=True)
                    continue
                t0 = time.perf_counter()
                r = subprocess.run(
                    [sys.executable, "-m", "repro.launch.dryrun",
                     "--arch", arch, "--shape", shape, "--multi-pod", mp,
                     "--tag", args.tag],
                    env=env, cwd=ROOT, timeout=None,
                    capture_output=True, text=True,
                    **({} if args.timeout == 0 else {}))
                out = (r.stdout or "").strip().splitlines()
                msg = out[-1] if out else f"rc={r.returncode}"
                if r.returncode != 0 and "[ok]" not in msg and "[skipped]" not in msg:
                    # record crash-level failures (OOM kill etc.)
                    p = os.path.join(ART, cell + ".json")
                    if not os.path.exists(p):
                        json.dump(dict(arch=arch, shape=shape, mesh=meshname,
                                       tag=args.tag, status="error",
                                       error=f"subprocess rc={r.returncode}: "
                                       + (r.stderr or "")[-400:]),
                                  open(p, "w"), indent=1)
                print(f"{msg}  [{time.perf_counter()-t0:.0f}s]", flush=True)


if __name__ == "__main__":
    main()
