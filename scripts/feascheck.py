"""Feasibility probe: 512 fake CPU devices, sharded compile, cost/memory analysis."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import time
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

t0 = time.perf_counter()
mesh = jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
print("mesh built", time.perf_counter() - t0, "s; ndev", len(jax.devices()))


def step(x, w1, w2):
    h = jnp.einsum("bd,df->bf", x, w1)
    h = jax.nn.relu(h)
    return jnp.einsum("bf,fd->bd", h, w2)


B, D, F = 4096, 2048, 8192
x = jax.ShapeDtypeStruct((B, D), jnp.bfloat16)
w1 = jax.ShapeDtypeStruct((D, F), jnp.bfloat16)
w2 = jax.ShapeDtypeStruct((F, D), jnp.bfloat16)

with mesh:
    f = jax.jit(
        step,
        in_shardings=(
            NamedSharding(mesh, P(("pod", "data"), None)),
            NamedSharding(mesh, P(None, "model")),
            NamedSharding(mesh, P("model", None)),
        ),
        out_shardings=NamedSharding(mesh, P(("pod", "data"), None)),
    )
    t0 = time.perf_counter()
    lowered = f.lower(x, w1, w2)
    print("lower:", time.perf_counter() - t0, "s")
    t0 = time.perf_counter()
    compiled = lowered.compile()
    print("compile:", time.perf_counter() - t0, "s")
    ma = compiled.memory_analysis()
    print("memory_analysis:", ma)
    ca = compiled.cost_analysis()
    print("cost keys:", {k: v for k, v in ca.items() if "flops" in k or "bytes" in k})
    txt = compiled.as_text()
    import re
    colls = re.findall(r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)", txt)
    print("collective op mentions:", len(colls))
    # expected per-device flops: 2*B*D*F*2 / 512 ≈ 2*4096*2048*8192*2/512
    print("expected per-dev flops:", 2 * B * D * F * 2 / 512, "reported:", ca.get("flops"))
