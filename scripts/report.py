"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts."""
import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(ROOT, "artifacts", "dryrun")
PEAK, HBM, ICI, DCN = 197e12, 819e9, 4 * 50e9, 25e9


def rows(tag="baseline"):
    out = []
    for p in sorted(glob.glob(os.path.join(ART, f"*__{tag}.json"))):
        out.append(json.load(open(p)))
    return out


def terms(r):
    h = r["hlo"]
    comp = h["dot_flops"] / PEAK
    coll = h["coll_bytes_ici"] / ICI + h["coll_bytes_dcn"] / DCN
    mem_lo = (r["memory"]["argument_bytes"] + r["memory"]["output_bytes"]) / HBM
    mem_hi = h["out_bytes"] / HBM
    # classify with the FUSED memory estimate (mem_lo): on TPU the unfused
    # per-op bound (mem_hi) never materializes for matmul-dominated steps
    dom = max((comp, "compute"), (mem_lo, "memory"), (coll, "collective"))
    ratio = r["model_flops"] / max(r["n_chips"] * h["dot_flops"], 1.0)
    frac = r["model_flops"] / r["n_chips"] / PEAK / max(dom[0], 1e-12)
    return comp, mem_lo, mem_hi, coll, dom[1], ratio, frac


def main():
    rs = rows()
    print("### §Dry-run (per (arch × shape × mesh) cell)\n")
    print("| arch | shape | mesh | status | compile s | peak GiB/dev (CPU) | peak GiB/dev (TPU-corrected) | ICI GiB/dev | DCN GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rs:
        if r["status"] == "ok":
            m = r["memory"]
            h = r["hlo"]
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                  f"{r['compile_s']} | {m['peak_bytes']/2**30:.2f} | "
                  f"{m.get('peak_bytes_tpu', m['peak_bytes'])/2**30:.2f} | "
                  f"{h['coll_bytes_ici']/2**30:.2f} | "
                  f"{h['coll_bytes_dcn']/2**30:.2f} |")
        else:
            reason = r.get("reason", r.get("error", ""))[:60]
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"{r['status']} | | | | | {reason} |")

    print("\n### §Roofline (single-pod 16×16 = 256 chips)\n")
    print("| arch | shape | compute s | memory s (lo..hi) | collective s | bottleneck | MODEL/HLO flops | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rs:
        if r["status"] != "ok" or r["mesh"] != "16x16":
            continue
        c, ml, mh, co, dom, ratio, frac = terms(r)
        print(f"| {r['arch']} | {r['shape']} | {c:.4f} | {ml:.4f}..{mh:.4f} | "
              f"{co:.4f} | {dom} | {ratio:.3f} | {frac:.3f} |")


if __name__ == "__main__":
    main()
